"""Cluster front-end dispatch policies.

The node-level FIFO+CFS hybrid only sees the invocations the cluster
dispatcher hands it, so the routing layer bounds how much money the
per-node scheduler can save. Eight policies spanning the design space of
the related work:

random            -- seeded uniform choice (the strawman baseline).
round_robin       -- cyclic assignment, oblivious to node state.
least_loaded      -- route to the node with the fewest admitted-but-
                     unfinished tasks per core (power-of-d with d = N).
join_idle_queue   -- pull-based dispatch a la Hiku: nodes advertise
                     idleness; an invocation goes to the idle node that
                     has waited longest, falling back to least-loaded
                     when the idle queue is empty.
affinity          -- consistent-hash function affinity a la Kaffes et
                     al.: invocations of one function land on one node
                     (warm containers, code locality), with a
                     virtual-node ring so node add/remove only remaps
                     ~1/N of functions.
warm_affinity     -- affinity that routes on the ACTUAL warm set from
                     node heartbeats: any node already holding a warm
                     sandbox for the function wins; otherwise the ring
                     owner, spilling to least-loaded past a load bound.
least_loaded_warm -- least-loaded with warm tie-breaking: among nodes
                     within a load slack of the minimum, prefer one with
                     a warm sandbox for the function.
cost_aware        -- prices each route in dollars: expected cold-start
                     penalty x the function's per-ms price, plus a
                     queueing term converting node load into billed-ms
                     (contention inflates wall-clock execution under
                     CFS). Routes to the cheapest node. The load-to-
                     billed-ms coefficient is LEARNED online from
                     completion feedback (recursive least squares with
                     forgetting; the configured constant is the prior).

All policies are deterministic under a fixed seed. ``select`` sees the
live node handles and the cluster clock; node state is whatever the
scheduler's ``load_snapshot`` reports at that instant — including the
warm-set contents when the container lifecycle layer is attached.
"""
from __future__ import annotations

import bisect
import hashlib
import random
from collections import OrderedDict
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .sim import ClusterNode

from ..core.containers import expected_cold_ms
from ..core.cost import price_per_ms
from ..core.events import Task
from ..costmodel.online import ScalarRLS


class Dispatcher:
    name = "base"
    # Learning dispatchers set this; the fleet loop then feeds every
    # completion back via observe_completion (in canonical
    # (completion, tid) order, so feedback never depends on node order).
    wants_feedback = False
    # Failure-domain topology, attached by the fleet when one exists.
    topology = None

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = random.Random(seed)

    def select(self, task: Task, nodes: Sequence["ClusterNode"],
               t: float) -> int:
        """Return the index into ``nodes`` this task is routed to."""
        raise NotImplementedError

    def on_topology_change(self, nodes: Sequence["ClusterNode"]) -> None:
        """Called when nodes join or leave the fleet."""

    def attach_topology(self, topology) -> None:
        """Called once, before the first ``on_topology_change``, when
        the fleet carries a failure-domain topology. Base dispatchers
        ignore it; ``cost_aware`` prices SKU multipliers and cross-zone
        hops with it."""
        self.topology = topology

    def observe_completion(self, task: Task) -> None:
        """Completion feedback hook (only called when wants_feedback)."""


class RandomDispatch(Dispatcher):
    name = "random"

    def select(self, task, nodes, t):
        return self.rng.randrange(len(nodes))


class RoundRobinDispatch(Dispatcher):
    name = "round_robin"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._next = 0

    def select(self, task, nodes, t):
        i = self._next % len(nodes)
        self._next += 1
        return i


class LeastLoadedDispatch(Dispatcher):
    name = "least_loaded"

    def select(self, task, nodes, t):
        return min(range(len(nodes)),
                   key=lambda i: (nodes[i].snapshot()["load"], i))


class JoinIdleQueueDispatch(Dispatcher):
    """Pull-based: an ordered set of idle node ids, longest-idle first.

    A real Hiku-style worker pulls work when it idles; in the
    simulation the equivalent information arrives with the snapshot we
    take at each dispatch decision, so the idle queue is refreshed then.
    """

    name = "join_idle_queue"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._idle: OrderedDict[int, None] = OrderedDict()

    def select(self, task, nodes, t):
        snaps = [n.snapshot() for n in nodes]
        for i, s in enumerate(snaps):
            if s["idle"]:
                if i not in self._idle:
                    self._idle[i] = None
            else:
                self._idle.pop(i, None)
        if self._idle:
            i, _ = self._idle.popitem(last=False)
            return i
        return min(range(len(nodes)), key=lambda i: (snaps[i]["load"], i))

    def on_topology_change(self, nodes):
        self._idle.clear()


def _hash64(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(),
                                          digest_size=8).digest(), "big")


class AffinityDispatch(Dispatcher):
    """Consistent-hash ring over (node id, virtual replica) points keyed
    by ``func_id``: the per-function-invocation affinity scheduler of
    Kaffes et al., made elastic."""

    name = "affinity"

    def __init__(self, seed: int = 0, vnodes: int = 64):
        super().__init__(seed)
        self.vnodes = vnodes
        self._ring: list[tuple[int, int]] = []  # (point, node index)
        self._points: list[int] = []

    def _build(self, nodes) -> None:
        self._ring = sorted(
            (_hash64(f"{n.node_id}:{v}:{self.seed}"), i)
            for i, n in enumerate(nodes) for v in range(self.vnodes))
        self._points = [p for p, _ in self._ring]

    def on_topology_change(self, nodes):
        self._build(nodes)

    def select(self, task, nodes, t):
        return self.owner(task.func_id, nodes)

    def owner(self, func_id: int, nodes) -> int:
        """Ring lookup without dispatching (affinity-stability tests)."""
        if len(self._ring) != len(nodes) * self.vnodes:
            self._build(nodes)
        j = bisect.bisect_right(self._points, _hash64(f"f{func_id}"))
        return self._ring[j % len(self._ring)][1]


class WarmAffinityDispatch(AffinityDispatch):
    """Affinity routing on observed warm state, not just the hash ring.

    The ring concentrates a function on one node, which is what *builds*
    warmth — but heartbeats know where warm sandboxes actually are (a
    node added last minute owns ring ranges it has never served; a
    capacity eviction can leave the ring owner cold while a spill target
    is warm). Preference order: warm node (least-loaded among them) >
    ring owner while its load is below ``spill_load`` > least-loaded.
    """

    name = "warm_affinity"

    def __init__(self, seed: int = 0, vnodes: int = 64,
                 spill_load: float = 2.0):
        super().__init__(seed, vnodes)
        self.spill_load = spill_load

    def select(self, task, nodes, t):
        snaps = [n.snapshot() for n in nodes]
        warm = [i for i, s in enumerate(snaps)
                if s.get("warm", {}).get(task.func_id)]
        if warm:
            return min(warm, key=lambda i: (snaps[i]["load"], i))
        home = self.owner(task.func_id, nodes)
        if snaps[home]["load"] <= self.spill_load:
            return home
        return min(range(len(nodes)), key=lambda i: (snaps[i]["load"], i))


class WarmLeastLoadedDispatch(LeastLoadedDispatch):
    """Least-loaded with warm tie-breaking: load balance first, but when
    several nodes are within ``slack`` load of the minimum, take the one
    already holding a warm sandbox for this function."""

    name = "least_loaded_warm"

    def __init__(self, seed: int = 0, slack: float = 0.5):
        super().__init__(seed)
        self.slack = slack

    def select(self, task, nodes, t):
        snaps = [n.snapshot() for n in nodes]
        lo = min(s["load"] for s in snaps)
        cands = [i for i, s in enumerate(snaps)
                 if s["load"] <= lo + self.slack]
        warm = [i for i in cands
                if snaps[i].get("warm", {}).get(task.func_id)]
        pool = warm or cands
        return min(pool, key=lambda i: (snaps[i]["load"], i))


class CostAwareDispatch(Dispatcher):
    """Route by estimated marginal dollars, not queue lengths.

    score(node) = cold_penalty_ms x price_per_ms(mem)
                + load x queue_ms_per_load x price_per_ms(mem)

    The first term is the billed sandbox boot the user pays if the node
    has no warm container for the function (zero on nodes without a
    container layer); the second converts node load into an equivalent
    billed-ms penalty — under fair-share scheduling, contention directly
    inflates the wall-clock execution the provider meters.

    The conversion coefficient is LEARNED online (``learn=True``, the
    default): the fleet loop feeds completions back, each yielding one
    observation (load at dispatch, billed-ms inflation over the pure
    demand: execution - init - service). A scalar recursive
    least-squares fit through the origin with forgetting factor
    ``rls_lambda`` tracks inflation-per-unit-load; ``queue_ms_per_load``
    seeds it as a prior worth ``prior_weight`` squared-load units of
    evidence, so an unobserved fleet routes exactly like the fixed-
    coefficient dispatcher and the estimate moves only as real evidence
    accumulates. Everything is deterministic: no sampling, and feedback
    arrives in canonical (completion, tid) order.

    The estimator itself is ``costmodel.online.ScalarRLS`` — the online
    half of the cost-model substrate. A learned ``CostModel`` seeds
    ``queue_ms_per_load`` with its calibrated coefficient (and may
    share its RLS instance outright via ``rls=``, so routing and the
    model report one value); ``pricing`` prices routes with a
    non-default :class:`~repro.costmodel.pricing.PricingSpec`.
    ``snapshot()`` exposes the learned state (coefficient, observation
    count, realized prediction error) for the summary schema.
    """

    name = "cost_aware"

    def __init__(self, seed: int = 0, queue_ms_per_load: float = 1_000.0,
                 learn: bool = True, rls_lambda: float = 0.98,
                 prior_weight: float = 25.0, pricing=None, rls=None):
        super().__init__(seed)
        self.queue_ms_per_load = queue_ms_per_load
        self.learn = learn
        # A frozen dispatcher must not make the fleet loop harvest
        # completions it will ignore.
        self.wants_feedback = learn
        self.rls_lambda = rls_lambda
        self.pricing = pricing
        self.rls = rls if rls is not None else ScalarRLS(
            queue_ms_per_load, prior_weight=prior_weight,
            lam=rls_lambda, learn=learn)
        # tid -> load of the chosen node at dispatch time.
        self._dispatch_load: dict[int, float] = {}

    @property
    def coeff(self) -> float:
        """Current load -> billed-ms conversion (the learned slope)."""
        if not self.learn:
            return self.queue_ms_per_load
        return self.rls.coeff

    @property
    def n_observed(self) -> int:
        return self.rls.n_observed

    def snapshot(self) -> dict:
        """Learned-state roll-up (summary schema: cost_coeff /
        cost_obs / cost_pred_err_ms)."""
        return {
            "coeff": self.coeff,
            "n_observed": self.rls.n_observed,
            "queue_ms_per_load": self.queue_ms_per_load,
            "mean_abs_err_ms": self.rls.mean_abs_err,
            "learn": self.learn,
        }

    def observe_completion(self, task):
        load = self._dispatch_load.pop(task.tid, None)
        if not self.learn or load is None or load <= 0.0:
            return  # a zero-load dispatch carries no slope information
        if task.completion is None or task.first_run is None:
            return
        inflation = max(0.0, task.execution - task.init_ms - task.service)
        self.rls.observe(load, inflation)

    def select(self, task, nodes, t):
        p = price_per_ms(task.mem_mb, self.pricing)
        coeff = self.coeff
        topo = self.topology
        home = topo.home_zone(task.func_id) if topo is not None else None
        best, best_score, best_load = 0, None, 0.0
        for i, node in enumerate(nodes):
            s = node.snapshot()
            cold = 0.0
            if "warm" in s and not s["warm"].get(task.func_id):
                # Price with the node's advertised cold-start model
                # (heartbeat), so overridden ContainerConfig knobs are
                # reflected in routing.
                base, per_gb = s.get("cold_model", (None, None))
                cold = expected_cold_ms(task.mem_mb) if base is None \
                    else expected_cold_ms(task.mem_mb, base, per_gb)
            score = cold * p + s["load"] * coeff * p
            # SKU-aware pricing: the billed-ms terms scale by the
            # node's effective $/ms multiplier (spot discount folded
            # in), and a dispatch outside the home zone adds the hop's
            # latency priced like billed time. Multiplying by an exact
            # 1.0 and adding nothing keeps flat fleets bit-identical.
            mult = getattr(node, "price_mult", 1.0)
            if mult != 1.0:
                score *= mult
            if home is not None and node.zone is not None \
                    and node.zone != home:
                score += topo.cross_zone_ms * p
            if best_score is None or score < best_score:
                best, best_score, best_load = i, score, s["load"]
        if self.learn:
            self._dispatch_load[task.tid] = best_load
        return best


DISPATCHERS = {
    "random": RandomDispatch,
    "round_robin": RoundRobinDispatch,
    "least_loaded": LeastLoadedDispatch,
    "join_idle_queue": JoinIdleQueueDispatch,
    "affinity": AffinityDispatch,
    "warm_affinity": WarmAffinityDispatch,
    "least_loaded_warm": WarmLeastLoadedDispatch,
    "cost_aware": CostAwareDispatch,
}


def make_dispatcher(name: str, **kw) -> Dispatcher:
    if name not in DISPATCHERS:
        raise KeyError(f"unknown dispatcher {name!r}; "
                       f"have {sorted(DISPATCHERS)}")
    return DISPATCHERS[name](**kw)
