"""repro.cluster — multi-node fleet simulation over per-node schedulers.

The paper stops at one 50-core host; a provider runs fleets, and the
cluster dispatcher decides which node an invocation lands on before the
node-level FIFO+CFS hybrid ever sees it. This package composes the
single-node simulators into a fleet: pluggable front-end dispatch
(``dispatch``), the interleaved multi-node event loop (``sim``),
fleet-level roll-ups (``metrics``), and a parallel grid runner
(``sweep``).
"""
from .admission import AdmissionConfig, AdmissionControl, make_admission
from .chaos import (ChaosEvent, ChaosSchedule, churn_preset, kill_heal,
                    zone_failure_preset)
from .dispatch import (DISPATCHERS, AffinityDispatch, CostAwareDispatch,
                       Dispatcher, JoinIdleQueueDispatch,
                       LeastLoadedDispatch, RandomDispatch,
                       RoundRobinDispatch, WarmAffinityDispatch,
                       WarmLeastLoadedDispatch, make_dispatcher)
from .metrics import ClusterResult
from .prewarm import PrewarmConfig, Provisioner, build_plan
from .retry import RetryPolicy, RetryState, make_retry
from .sim import ClusterNode, ClusterSim, run_cluster
from .sweep import (PRESETS, Cell, build_grid, compare_serial, merge_rows,
                    run_cell, run_sweep, shard_grid)
from .topology import SKUS, NodePlacement, NodeSKU, TopologySpec, as_sku

__all__ = [
    "DISPATCHERS", "AffinityDispatch", "CostAwareDispatch", "Dispatcher",
    "JoinIdleQueueDispatch", "LeastLoadedDispatch", "RandomDispatch",
    "RoundRobinDispatch", "WarmAffinityDispatch",
    "WarmLeastLoadedDispatch", "make_dispatcher", "ClusterResult",
    "ClusterNode", "ClusterSim", "run_cluster", "PRESETS", "Cell",
    "build_grid", "compare_serial", "run_cell", "run_sweep",
    "AdmissionConfig", "AdmissionControl", "make_admission",
    "ChaosEvent", "ChaosSchedule", "churn_preset", "kill_heal",
    "PrewarmConfig", "Provisioner", "build_plan", "merge_rows",
    "shard_grid", "zone_failure_preset", "RetryPolicy", "RetryState",
    "make_retry", "SKUS", "NodePlacement", "NodeSKU", "TopologySpec",
    "as_sku",
]
