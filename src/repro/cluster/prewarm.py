"""Predictive container pre-warming from the trace's per-minute counts.

The container layer (``core.containers``) is purely *reactive*: a
sandbox only exists because some invocation already paid a cold start
for it, so the first wave of every per-minute burst is billed sandbox
boot. Providers know better — the Azure trace's per-minute invocation
counts are exactly the signal Shahrad et al.'s histogram policy keeps
per function — so this module turns that signal into a *provisioning
plan*: for each function and minute, place the expected steady-state
concurrency's worth of warm sandboxes ``lead_ms`` before the minute
starts, via :meth:`ContainerPool.prewarm` (which never evicts an
observed-warm container to make room for a bet, and whose idle memory
meters into the provider-side hold cost — pre-warming is a wager that
saved billed-init exceeds idle DRAM).

The plan is pure data: ``build_plan`` folds a task list into
``(t, func_id, mem_mb, n)`` rows; the :class:`Provisioner` walks them as
the fleet loop advances and routes each row to a node — the dispatcher's
consistent-hash ``owner`` when it has one (warmth placed where affinity
will route), else round-robin by function id. Everything is
deterministic given the workload.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass
from typing import Optional, Sequence

MINUTE_MS = 60_000.0


@dataclass(frozen=True)
class PrewarmConfig:
    """Provisioning-plan knobs."""

    lead_ms: float = 2_000.0     # provision this far before each minute
    min_per_min: int = 2         # ignore functions below this rate
    max_per_func: int = 8        # per-function per-minute sandbox cap
    headroom: float = 1.0        # scale on the expected concurrency
    keepalive_ms: Optional[float] = None  # None = the pool's own policy
    # Where the per-minute rate comes from: "oracle" reads the trace's
    # own counts (the historical planner, bit-identical default);
    # "ewma" forecasts minute m from minutes < m via an online EWMA
    # (costmodel.forecast) — what a real provider can actually do.
    forecast: str = "oracle"
    ewma_alpha: float = 0.5


def make_prewarm_config(config) -> PrewarmConfig:
    """Coerce ``None`` / kwargs dict / ``PrewarmConfig`` — the same
    accept-anything contract the container layer's
    ``as_container_config`` gives the other spec-shaped arguments."""
    if config is None:
        return PrewarmConfig()
    if isinstance(config, PrewarmConfig):
        return config
    if isinstance(config, dict):
        return PrewarmConfig(**config)
    raise TypeError(f"cannot build PrewarmConfig from {type(config)!r}")


def per_minute_counts(tasks) -> dict[int, dict[int, int]]:
    """func_id -> {minute -> invocation count}: the trace signal the
    planner (and a real provider's forecaster) reads."""
    counts: dict[int, dict[int, int]] = defaultdict(lambda: defaultdict(int))
    for t in tasks:
        counts[t.func_id][int(t.arrival // MINUTE_MS)] += 1
    return {f: dict(m) for f, m in counts.items()}


def build_plan(tasks, config: Optional[PrewarmConfig] = None,
               ) -> list[tuple[float, int, int, int]]:
    """Fold a workload into provisioning rows ``(t, func_id, mem_mb, n)``
    sorted by time.

    ``n`` is the function's expected steady-state concurrency in that
    minute (count x mean service / 60 s, times ``headroom``), clamped to
    [1, ``max_per_func``] — one warm sandbox absorbs the burst front of
    a sparse function; a hot function gets enough to cover overlap.
    Minute 0 clamps to t=0: those rows sort before any arrival at the
    same instant, which is exactly when a just-in-time provisioner
    would have acted.
    """
    cfg = make_prewarm_config(config)
    svc_sum: dict[int, float] = defaultdict(float)
    svc_n: dict[int, int] = defaultdict(int)
    mem: dict[int, int] = {}
    for t in tasks:
        svc_sum[t.func_id] += t.service
        svc_n[t.func_id] += 1
        mem[t.func_id] = t.mem_mb
    rows = []
    for fid, minutes in per_minute_counts(tasks).items():
        mean_svc = svc_sum[fid] / svc_n[fid]
        for minute, count in minutes.items():
            if count < cfg.min_per_min:
                continue
            conc = count * mean_svc / MINUTE_MS * cfg.headroom
            n = max(1, min(cfg.max_per_func, math.ceil(conc)))
            t_prov = max(0.0, minute * MINUTE_MS - cfg.lead_ms)
            rows.append((t_prov, fid, mem[fid], n))
    rows.sort()
    return rows


class Provisioner:
    """Applies a plan to a live fleet as the clock passes each row.

    Placement: a dispatcher exposing ``owner(func_id, nodes)`` (the
    affinity family) decides — warmth goes where routing will look for
    it; otherwise rows spread round-robin by ``func_id`` so no single
    node's pool absorbs the whole bet. Nodes without a container pool
    are skipped (counted as ``skipped``).
    """

    def __init__(self, plan: Sequence[tuple], config: Optional[PrewarmConfig]
                 = None):
        self.plan = sorted(plan)
        self.cfg = make_prewarm_config(config)
        self._next = 0
        self.requested = 0   # sandboxes the plan asked for
        self.placed = 0      # actually admitted by pools (capacity-capped)
        self.skipped = 0     # rows with no pool to place into
        self.rows_applied = 0

    @classmethod
    def from_workload(cls, tasks, config: Optional[PrewarmConfig] = None,
                      ) -> "Provisioner":
        cfg = make_prewarm_config(config)
        if cfg.forecast != "oracle":
            from ..costmodel.forecast import make_plan
            return cls(make_plan(tasks, cfg), cfg)
        return cls(build_plan(tasks, cfg), cfg)

    def pending_at(self, t: float) -> bool:
        return self._next < len(self.plan) and self.plan[self._next][0] <= t

    def next_time(self) -> float:
        return self.plan[self._next][0] if self._next < len(self.plan) \
            else float("inf")

    def apply_due(self, t: float, nodes, dispatcher) -> int:
        """Provision every row with time <= ``t``; returns sandboxes
        placed. The fleet loop calls this before dispatching any
        arrival at ``t`` (provisioning at an instant precedes arrivals
        at it — the canonical tie rule the pool uses too)."""
        placed = 0
        owner = getattr(dispatcher, "owner", None)
        while self._next < len(self.plan) and self.plan[self._next][0] <= t:
            t_prov, fid, mem_mb, n = self.plan[self._next]
            self._next += 1
            self.rows_applied += 1
            self.requested += n
            if not nodes:
                self.skipped += 1
                continue
            if owner is not None:
                node = nodes[owner(fid, nodes)]
            else:
                node = nodes[fid % len(nodes)]
            pool = getattr(node.sched, "containers", None)
            if pool is None:
                self.skipped += 1
                continue
            # The node's clock may lag t (it is stepped per arrival);
            # provision at the later of the two so the pool never sees
            # time run backwards.
            placed += pool.prewarm(fid, mem_mb, max(t_prov, node.sched.now),
                                   n, keepalive_ms=self.cfg.keepalive_ms)
        self.placed += placed
        return placed

    def stats(self) -> dict:
        return {
            "requested": self.requested,
            "placed": self.placed,
            "skipped": self.skipped,
            "rows_applied": self.rows_applied,
            "rows_total": len(self.plan),
        }
