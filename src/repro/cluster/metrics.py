"""Fleet-level metric and cost roll-ups.

A cluster run is judged on three axes the single-node ``SimResult``
cannot express:

* balance  — per-node utilization spread (a dispatcher that piles work
             on one node wastes the rest of the fleet);
* latency  — fleet-wide slowdown (turnaround / service) percentiles,
             which normalize across the heavy-tailed duration mix;
* money    — total $ via the same AWS Lambda model as the paper
             (``core.cost``), summed over every node; with containers
             modelled, split into the cold-start share of the user bill
             plus the provider-side warm-pool memory-hold cost.

Tasks in these roll-ups come from each node's ``completed`` list, so
their metrics are defined; ``failed`` invocations are counted
separately and never enter latency/cost vectors.

Like the single-node roll-ups, the fleet roll-ups are ORDER-CANONICAL
(DESIGN.md Sec. 13): the task view is sorted by (completion, tid) and
money sums are exactly rounded, so summaries are bit-identical under
any permutation of each node's completed list.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional

import numpy as np

from ..core.cost import (duration_cost_usd, rejected_request_cost_usd,
                         workload_cost_usd)
from ..core.metrics import SimResult


@dataclass
class ClusterResult:
    node_results: list[SimResult]
    node_ids: list[str]
    node_policies: list[str]
    dispatcher: str
    cores_per_node: int
    assignments: list = field(default_factory=list)
    redispatches: int = 0  # straggler re-dispatches (serving fleets)
    n_retired: int = 0  # trailing node_results rows removed mid-run
    # -- resilience layers (DESIGN.md Sec. 14) ------------------------------
    shed: list = field(default_factory=list)       # front-door rejects
    chaos_events: list = field(default_factory=list)
    admission: Optional[dict] = None               # AdmissionControl.stats()
    prewarm_stats: Optional[dict] = None           # Provisioner.stats()
    # -- failure-domain topology (DESIGN.md Sec. 17) ------------------------
    # One dict per node_results row: zone/rack/SKU labels and price
    # multipliers (empty list on flat fleets — every multiplier 1.0).
    node_meta: list = field(default_factory=list)
    cross_zone: int = 0                            # out-of-zone dispatches
    retry_stats: Optional[dict] = None             # RetryState.stats()
    degraded_ms: float = 0.0                       # sum of degrade intervals
    # -- cost-model substrate (DESIGN.md Sec. 18) ---------------------------
    # Learned dispatcher state (CostAwareDispatch.snapshot(): coeff /
    # n_observed / mean_abs_err_ms), None for stateless dispatchers.
    dispatcher_state: Optional[dict] = None
    # PricingSpec the roll-ups bill with (None = DEFAULT_PRICING,
    # bit-identically). Set post-run by the Scenario layer.
    pricing: Optional[object] = None

    # -- task views (cached: summary() walks these repeatedly) --------------
    @cached_property
    def tasks(self) -> list:
        """Fleet-wide finished tasks in canonical (completion, tid)
        order — node order and per-node list order cannot leak into any
        derived metric."""
        return sorted((t for r in self.node_results for t in r.tasks
                       if t.completion is not None),
                      key=lambda t: (t.completion, t.tid))

    @cached_property
    def failed(self) -> list:
        return [t for r in self.node_results for t in r.failed]

    def execution(self) -> np.ndarray:
        return np.array([t.execution for t in self.tasks])

    def slowdown(self) -> np.ndarray:
        return np.array([t.turnaround / t.service for t in self.tasks])

    # -- balance ------------------------------------------------------------
    def makespan(self) -> float:
        # Canonical order: last wins. A fleet can finish NOTHING (chaos
        # killed every node / admission shed everything) — that is a
        # reportable outcome, not a crash.
        return self.tasks[-1].completion if self.tasks else 0.0

    @property
    def live_results(self) -> list[SimResult]:
        """Nodes still in the fleet (retired rows sort last)."""
        n = len(self.node_results) - self.n_retired
        return self.node_results[:n]

    def node_utilization(self, horizon: float = None) -> np.ndarray:
        """Busy fraction per LIVE node over the fleet makespan — a node
        removed mid-run would otherwise read as dispatcher imbalance."""
        if horizon is None:
            horizon = self.makespan()
        if horizon <= 0.0:
            return np.zeros(len(self.live_results))
        out = []
        for r in self.live_results:
            busy = math.fsum(t.cpu_time for t in r.tasks)
            out.append(busy / (self.cores_per_node * horizon))
        return np.array(out)

    def utilization_spread(self) -> dict[str, float]:
        u = self.node_utilization()
        return {"min": float(u.min()), "max": float(u.max()),
                "range": float(u.max() - u.min()), "std": float(u.std())}

    def assignment_counts(self) -> list[int]:
        """Per-node assignment totals, in ``node_results`` order.
        Assignments are keyed by node id, which survives add/remove
        churn (result rows reorder: live nodes first, retired last)."""
        pos = {nid: k for k, nid in enumerate(self.node_ids)}
        counts = [0] * len(self.node_ids)
        for _, nid in self.assignments:
            counts[pos[nid]] += 1
        return counts

    # -- latency / money ----------------------------------------------------
    def p_slowdown(self, pct: float) -> float:
        return float(np.percentile(self.slowdown(), pct))

    def _price_mults(self) -> Optional[list]:
        """Per-node effective price multipliers, or None when every node
        bills at the flat rate (the historical — and bit-identical —
        single-sum path)."""
        if not self.node_meta:
            return None
        mults = [m.get("price_mult", 1.0) for m in self.node_meta]
        return mults if any(m != 1.0 for m in mults) else None

    def cost_usd(self) -> float:
        mults = self._price_mults()
        if mults is None:
            return workload_cost_usd(self.execution(),
                                     mem_mb=[t.mem_mb for t in self.tasks],
                                     pricing=self.pricing)
        # Heterogeneous SKUs: each node's bill is priced at ITS
        # multiplier over its own (completion, tid)-sorted completions,
        # then exactly summed — still order-canonical, because node_
        # results order is the fleet's deterministic roster order.
        per_node = []
        for r, mult in zip(self.node_results, mults):
            done = sorted((t for t in r.tasks if t.completion is not None),
                          key=lambda t: (t.completion, t.tid))
            per_node.append(workload_cost_usd(
                [t.execution for t in done],
                mem_mb=[t.mem_mb for t in done], price_mult=mult,
                pricing=self.pricing))
        return math.fsum(per_node)

    def spot_savings_usd(self) -> float:
        """Money NOT billed because work landed on discounted spot
        capacity: each spot node's duration bill at its base SKU rate
        times its discount. Zero without a topology (or without spot
        nodes) — reported so the bench headline can show the price of
        chasing the discount (revocations requeue work) next to the
        discount itself."""
        if not self.node_meta:
            return 0.0
        out = []
        for r, m in zip(self.node_results, self.node_meta):
            if not m.get("spot") or not m.get("spot_discount"):
                continue
            done = sorted((t for t in r.tasks if t.completion is not None),
                          key=lambda t: (t.completion, t.tid))
            base = duration_cost_usd([t.execution for t in done],
                                     [t.mem_mb for t in done],
                                     pricing=self.pricing)
            out.append(base * m.get("base_price_mult", 1.0)
                       * m["spot_discount"])
        return math.fsum(out)

    def rejected_cost_usd(self) -> float:
        """Per-request fees incurred by admission-shed invocations —
        reported separately so shedding never masquerades as savings."""
        return rejected_request_cost_usd(len(self.shed), self.pricing)

    def total_cost_usd(self) -> float:
        """User-facing bill including rejected-request fees."""
        return self.cost_usd() + self.rejected_cost_usd()

    def requeued(self) -> int:
        """Invocations re-dispatched after a chaos kill — lost in-flight
        work plus concurrency-slot waiters stranded on dead nodes."""
        return sum(e.get("requeued", 0) + e.get("slot_requeued", 0)
                   for e in self.chaos_events)

    def revoked(self) -> int:
        """Nodes reclaimed by spot revocation events."""
        return sum(e.get("revoked", 0) for e in self.chaos_events)

    # -- container lifecycle ------------------------------------------------
    # Fleet values aggregate the per-node SimResult helpers so the
    # definitions (what counts as cold, how init is priced) live in
    # exactly one place: core.metrics.

    def cold_starts(self) -> int:
        return sum(r.cold_starts() for r in self.node_results)

    def cold_start_rate(self) -> float:
        return (self.cold_starts() / len(self.tasks)) if self.tasks else 0.0

    def init_cost_usd(self) -> float:
        """Cold-start share of the fleet's user-facing bill."""
        return sum(r.init_cost_usd() for r in self.node_results)

    def warm_hold_usd(self) -> float:
        """Provider-side warm-pool memory-hold cost, fleet-wide."""
        return sum(r.warm_hold_usd() for r in self.node_results)

    def container_stats(self) -> dict | None:
        """Fleet-wide pool counters (None when no node has a pool)."""
        per_node = [r.container_stats for r in self.node_results
                    if r.container_stats is not None]
        if not per_node:
            return None
        keys = ("warm_hits", "cold_starts", "evictions_ttl",
                "evictions_capacity", "evictions_flush", "dropped",
                "prewarmed", "warm_mb_ms", "queued_concurrency",
                "granted_from_queue")
        agg = {k: sum(s[k] for s in per_node) for k in keys}
        total = agg["warm_hits"] + agg["cold_starts"]
        agg["cold_start_rate"] = (agg["cold_starts"] / total) if total else 0.0
        return agg

    def summary(self) -> dict:
        # Compute each derived array once: this runs per sweep cell on
        # the grid-runner hot path. Empty percentile inputs (a fleet
        # that completed nothing) report as zero, not as a crash.
        slowdown = self.slowdown() if self.tasks else np.zeros(1)
        horizon = self.makespan()
        util = self.node_utilization(horizon)
        if util.size == 0:          # chaos can retire the whole fleet
            util = np.zeros(1)
        turnaround = [t.turnaround for t in self.tasks] or [0.0]
        out = {
            "dispatcher": self.dispatcher,
            "node_policies": list(dict.fromkeys(self.node_policies)),
            "n_nodes": len(self.live_results),
            "cores_per_node": self.cores_per_node,
            "n": len(self.tasks),
            "failed": len(self.failed),
            "p50_slowdown": float(np.percentile(slowdown, 50)),
            "p99_slowdown": float(np.percentile(slowdown, 99)),
            "p99_turnaround_s": float(np.percentile(turnaround, 99)) / 1e3,
            "makespan_s": horizon / 1e3,
            "util_mean": float(util.mean()),
            "util_range": float(util.max() - util.min()),
            "util_std": float(util.std()),
            "cost_usd": self.cost_usd(),
            # Container economics: zeros when the fleet runs without the
            # lifecycle layer, so downstream CSV/JSON schemas are stable.
            "cold_starts": self.cold_starts(),
            "cold_start_rate": self.cold_start_rate(),
            "init_cost_usd": self.init_cost_usd(),
            "warm_hold_usd": self.warm_hold_usd(),
            # Resilience accounting: stable zeros when the layers are
            # off, so downstream JSON/CSV schemas never fork.
            "shed": len(self.shed),
            "rejected_cost_usd": self.rejected_cost_usd(),
            "requeued": self.requeued(),
            "chaos_events": len(self.chaos_events),
            "queued": (self.admission or {}).get("queued", 0),
            "spilled": (self.admission or {}).get("spilled", 0),
            "prewarmed": (self.prewarm_stats or {}).get("placed", 0),
            # Topology / retry accounting (DESIGN.md Sec. 17): stable
            # zeros when the fleet is flat and no retry policy is set.
            "retries": (self.retry_stats or {}).get("retries", 0),
            "retry_wait_ms": (self.retry_stats or {}).get(
                "retry_wait_ms", 0.0),
            "revoked": self.revoked(),
            "degraded_ms": self.degraded_ms,
            "cross_zone": self.cross_zone,
            "spot_savings_usd": self.spot_savings_usd(),
        }
        # Learned-coefficient state (cost-model substrate): stable
        # zeros when the dispatcher carries no estimator.
        ds = self.dispatcher_state or {}
        out["cost_coeff"] = ds.get("coeff", 0.0)
        out["cost_obs"] = ds.get("n_observed", 0)
        out["cost_pred_err_ms"] = ds.get("mean_abs_err_ms", 0.0)
        if self.redispatches:
            out["redispatches"] = self.redispatches
        return out
