"""Fleet-level metric and cost roll-ups.

A cluster run is judged on three axes the single-node ``SimResult``
cannot express:

* balance  — per-node utilization spread (a dispatcher that piles work
             on one node wastes the rest of the fleet);
* latency  — fleet-wide slowdown (turnaround / service) percentiles,
             which normalize across the heavy-tailed duration mix;
* money    — total $ via the same AWS Lambda model as the paper
             (``core.cost``), summed over every node.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

from ..core.cost import workload_cost_usd
from ..core.metrics import SimResult


@dataclass
class ClusterResult:
    node_results: list[SimResult]
    node_ids: list[str]
    node_policies: list[str]
    dispatcher: str
    cores_per_node: int
    assignments: list = field(default_factory=list)
    redispatches: int = 0  # straggler re-dispatches (serving fleets)
    n_retired: int = 0  # trailing node_results rows removed mid-run

    # -- task views (cached: summary() walks these repeatedly) --------------
    @cached_property
    def tasks(self) -> list:
        return [t for r in self.node_results for t in r.tasks]

    @cached_property
    def failed(self) -> list:
        return [t for r in self.node_results for t in r.failed]

    def execution(self) -> np.ndarray:
        return np.array([t.execution for t in self.tasks])

    def slowdown(self) -> np.ndarray:
        return np.array([t.turnaround / t.service for t in self.tasks])

    # -- balance ------------------------------------------------------------
    def makespan(self) -> float:
        return max(t.completion for t in self.tasks)

    @property
    def live_results(self) -> list[SimResult]:
        """Nodes still in the fleet (retired rows sort last)."""
        n = len(self.node_results) - self.n_retired
        return self.node_results[:n]

    def node_utilization(self, horizon: float = None) -> np.ndarray:
        """Busy fraction per LIVE node over the fleet makespan — a node
        removed mid-run would otherwise read as dispatcher imbalance."""
        if horizon is None:
            horizon = self.makespan()
        out = []
        for r in self.live_results:
            busy = sum(t.cpu_time for t in r.tasks)
            out.append(busy / (self.cores_per_node * horizon))
        return np.array(out)

    def utilization_spread(self) -> dict[str, float]:
        u = self.node_utilization()
        return {"min": float(u.min()), "max": float(u.max()),
                "range": float(u.max() - u.min()), "std": float(u.std())}

    def assignment_counts(self) -> list[int]:
        """Per-node assignment totals, in ``node_results`` order.
        Assignments are keyed by node id, which survives add/remove
        churn (result rows reorder: live nodes first, retired last)."""
        pos = {nid: k for k, nid in enumerate(self.node_ids)}
        counts = [0] * len(self.node_ids)
        for _, nid in self.assignments:
            counts[pos[nid]] += 1
        return counts

    # -- latency / money ----------------------------------------------------
    def p_slowdown(self, pct: float) -> float:
        return float(np.percentile(self.slowdown(), pct))

    def cost_usd(self) -> float:
        return workload_cost_usd(self.execution(),
                                 mem_mb=[t.mem_mb for t in self.tasks])

    def summary(self) -> dict:
        # Compute each derived array once: this runs per sweep cell on
        # the grid-runner hot path.
        slowdown = self.slowdown()
        horizon = self.makespan()
        util = self.node_utilization(horizon)
        turnaround = [t.turnaround for t in self.tasks]
        out = {
            "dispatcher": self.dispatcher,
            "node_policies": list(dict.fromkeys(self.node_policies)),
            "n_nodes": len(self.live_results),
            "cores_per_node": self.cores_per_node,
            "n": len(self.tasks),
            "failed": len(self.failed),
            "p50_slowdown": float(np.percentile(slowdown, 50)),
            "p99_slowdown": float(np.percentile(slowdown, 99)),
            "p99_turnaround_s": float(np.percentile(turnaround, 99)) / 1e3,
            "makespan_s": horizon / 1e3,
            "util_mean": float(util.mean()),
            "util_range": float(util.max() - util.min()),
            "util_std": float(util.std()),
            "cost_usd": self.cost_usd(),
        }
        if self.redispatches:
            out["redispatches"] = self.redispatches
        return out
