"""repro.distributed — sharding resolver, parameter descriptors,
gradient compression, elastic mesh helpers."""
from .sharding import (DEFAULT_RULES, ShardingCtx, current_ctx,
                       named_sharding, resolve_spec, shard, use_mesh)
from .params import (ParamSpec, abstract_params, count_params, is_spec,
                     materialize, param_shardings, param_specs_pspec,
                     tree_map_specs)

__all__ = [
    "DEFAULT_RULES", "ShardingCtx", "current_ctx", "named_sharding",
    "resolve_spec", "shard", "use_mesh", "ParamSpec", "abstract_params",
    "count_params", "is_spec", "materialize", "param_shardings",
    "param_specs_pspec", "tree_map_specs",
]
