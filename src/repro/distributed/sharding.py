"""Logical-axis sharding resolver (DESIGN.md Sec. 6).

Models annotate tensors with LOGICAL axis names ("batch", "heads",
"mlp", ...). The resolver maps logical names to mesh axes through
priority-ordered candidate chains, skipping candidates that do not
divide the dimension or whose mesh axes are already consumed by an
earlier dimension of the same tensor. This yields the fallback
behaviour the assigned archs need, e.g.:

* granite-moe (24 Q heads, 40 experts, vocab 49,155 on a 16-way model
  axis): heads/experts/vocab all fail divisibility and fall back, while
  the flattened head*head_dim projection dim (1536) and per-expert d_ff
  (512) still shard 16-way;
* GQA KV caches: "kv_heads" takes the model axis when divisible,
  otherwise the cache's sequence dim picks it up (flash-decode style
  sequence sharding - XLA inserts the partial-softmax collectives).

``use_mesh`` installs (mesh, rules) in a context; without a context all
annotations are no-ops so the same model code runs in single-device
tests.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# Candidate chains: logical axis -> list of mesh-axis tuples to try in
# order. None = replicate. "+pod" variants are appended automatically in
# multi-pod meshes for the data-parallel-like axes.
DEFAULT_RULES: dict[str, list[Optional[tuple[str, ...]]]] = {
    "batch":    [("pod", "data"), ("data",)],
    "seq":      [None],
    "embed":    [None],
    # weight dims
    "embed_w":  [("pod", "data"), ("data",)],   # FSDP / ZeRO-3 dim
    "heads":    [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [None],
    "kv":       [("model",)],                    # flattened kv*head_dim
    "qkv":      [("model",)],                    # flattened heads*head_dim
    "mlp":      [("model",)],
    "experts":  [("model",)],
    # MoE capacity dim: REPLICATED. Sharding it puts the dispatch
    # scatter/combine gather across shards and GSPMD inserts an
    # (S*K, d)-sized all-reduce per layer (measured 3.2 GB/layer on
    # granite train_4k) — replicating the per-row buffer is strictly
    # cheaper since batch is already data-sharded.
    "moe_cap":  [None],
    # MoE expert-weight d_model dim: when experts cannot take the model
    # axis (granite's 40e on 16), shard the CONTRACTING d dim instead —
    # the partial-sum all-reduce then happens on the small (E,C,f)
    # hidden (f=512) rather than the capacity-inflated (E,C,d) buffer
    # (measured 8 GB/layer -> ~0.7 GB/layer on granite train_4k).
    "moe_d":    [("model",), ("data",)],
    "vocab":    [("model",)],
    "kv_seq":   [("model",)],                    # cache seq (fallback TP)
    # CE logits chunk: when vocab cannot take the model axis (granite's
    # 49155), shard the chunked-CE sequence dim instead so the (B,cs,V)
    # logits never replicate.
    "ce_seq":   [("model",)],
    # attention batch: when kv_heads cannot take the model axis
    # (non-divisible GQA), reshard batch over data x model around the
    # attention block instead (Ulysses-style all-to-all) — zero
    # redundant compute whenever global_batch divides the full mesh.
    "attn_batch": [("pod", "data", "model"), ("data", "model"),
                   ("pod", "data"), ("data",)],
    "ssm":      [None],
    "conv":     [None],
}

# Dims with lower priority numbers claim mesh axes first (so e.g.
# kv_heads gets "model" before attn_batch can take it).
RESOLVE_PRIORITY = {
    "heads": 0, "kv_heads": 0, "experts": 0, "vocab": 0,
    "moe_d": 0.5,   # must claim "model" before "mlp" on w_down (E,f,d)
    "qkv": 1, "kv": 1, "mlp": 1, "moe_cap": 1, "kv_seq": 1, "ce_seq": 1,
    "embed_w": 2,
    "batch": 4, "attn_batch": 4,
}


@dataclass
class ShardingCtx:
    mesh: Mesh
    rules: dict[str, list[Optional[tuple[str, ...]]]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_STATE, "ctx", None)


@contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    prev = getattr(_STATE, "ctx", None)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _STATE.ctx = ShardingCtx(mesh=mesh, rules=merged)
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 ctx: Optional[ShardingCtx] = None) -> P:
    """Resolve logical axes to a PartitionSpec with fallback + used-axis
    tracking. ``axes`` entries may be None (replicated dim)."""
    ctx = ctx or current_ctx()
    if ctx is None:
        return P()
    mesh_axes = set(ctx.mesh.axis_names)
    used: set[str] = set()
    out: list = [None] * len(list(axes))
    order = sorted(range(len(out)),
                   key=lambda i: (RESOLVE_PRIORITY.get(list(axes)[i], 3), i))
    axes = list(axes)
    shape = list(shape)
    for i in order:
        name = axes[i]
        if name is None:
            continue
        candidates = ctx.rules.get(name, [None])
        chosen = None
        for cand in candidates:
            if cand is None:
                break
            cand_t = tuple(a for a in cand if a in mesh_axes)
            if not cand_t:
                continue
            if any(a in used for a in cand_t):
                continue
            size = int(np.prod([ctx.axis_size(a) for a in cand_t]))
            if dim_divides(shape[i], size):
                chosen = cand_t
                used.update(cand_t)
                break
        out[i] = (chosen if chosen is None or len(chosen) > 1
                  else chosen[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def dim_divides(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def shard(x, *axes: Optional[str]):
    """Annotate ``x`` with logical axes; no-op outside a mesh context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = resolve_spec(x.shape, axes, ctx)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                   ctx: Optional[ShardingCtx] = None) -> Optional[NamedSharding]:
    ctx = ctx or current_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, resolve_spec(shape, axes, ctx))
