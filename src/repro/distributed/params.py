"""Parameter descriptors: shapes + logical axes, materialized lazily.

Models build a pytree of :class:`ParamSpec` (pure metadata). The dry-run
converts it straight to ShapeDtypeStructs with NamedShardings (no
allocation); tests/examples materialize real arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import ShardingCtx, named_sharding, resolve_spec


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical axis names (or None), len == ndim
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def abstract_params(tree, ctx: Optional[ShardingCtx] = None):
    """ParamSpec tree -> ShapeDtypeStruct tree (with shardings if ctx)."""
    def to_abstract(p: ParamSpec):
        sharding = named_sharding(p.shape, p.axes, ctx)
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype),
                                    sharding=sharding)
    return tree_map_specs(to_abstract, tree)


def param_shardings(tree, ctx: Optional[ShardingCtx] = None):
    return tree_map_specs(lambda p: named_sharding(p.shape, p.axes, ctx),
                          tree)


def param_specs_pspec(tree, ctx: Optional[ShardingCtx] = None):
    return tree_map_specs(lambda p: resolve_spec(p.shape, p.axes, ctx), tree)


def materialize(tree, key, dtype: Optional[str] = None):
    """Materialize real arrays (tests / examples / training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        dt = jnp.dtype(dtype or p.dtype)
        if p.init == "zeros":
            arr = jnp.zeros(p.shape, dt)
        elif p.init == "ones":
            arr = jnp.ones(p.shape, dt)
        else:
            fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1], 1)
            std = p.scale / np.sqrt(fan_in)
            arr = (jax.random.normal(k, p.shape, jnp.float32) * std).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def count_params(tree) -> int:
    return sum(int(np.prod(p.shape))
               for p in jax.tree.leaves(tree, is_leaf=is_spec))
