"""Elastic scaling + straggler mitigation for long-running jobs.

On real fleets, device loss shows up as a failed collective; the
recovery path is: checkpoint-restore -> rebuild a smaller/larger mesh ->
re-lower the step. ``ElasticRunner`` packages that loop; on this CPU
container the mesh choices are simulated but the re-lowering is real.

``StepWatchdog`` is the training-side straggler detector: step times
beyond mean + k*std raise a signal the runner treats like a failure
(re-dispatch / re-mesh), mirroring the serving gateway's request
re-dispatch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from .sharding import use_mesh


@dataclass
class StepWatchdog:
    factor: float = 5.0
    min_samples: int = 5
    times: list = field(default_factory=list)

    def record(self, dt: float) -> bool:
        """Returns True if ``dt`` is a straggler step."""
        if len(self.times) >= self.min_samples:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if dt > mu + self.factor * sd and dt > 1.5 * mu:
                return True
        self.times.append(dt)
        if len(self.times) > 64:
            self.times.pop(0)
        return False


def viable_meshes(n_devices: int) -> list[tuple[int, int]]:
    """(data, model) factorizations, biggest model-parallel first."""
    out = []
    for model in range(min(n_devices, 64), 0, -1):
        if n_devices % model == 0:
            out.append((n_devices // model, model))
    return out


class ElasticRunner:
    """Re-mesh + re-lower on device-count changes."""

    def __init__(self, build_step: Callable, checkpoint_mgr=None):
        self.build_step = build_step      # (mesh_ctx) -> compiled step fn
        self.ckpt = checkpoint_mgr
        self.step_fn = None
        self.mesh = None

    def ensure(self, devices: Optional[list] = None):
        devices = devices if devices is not None else jax.devices()
        shape = viable_meshes(len(devices))[-1]
        dev = np.array(devices).reshape(shape)
        mesh = jax.sharding.Mesh(dev, ("data", "model"))
        if self.mesh is not None and mesh.shape == self.mesh.shape:
            return self.step_fn
        self.mesh = mesh
        with use_mesh(mesh) as ctx:
            self.step_fn = self.build_step(ctx)
        return self.step_fn
