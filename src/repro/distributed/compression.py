"""Gradient compression with error feedback (distributed-optimization
trick for multi-pod links).

int8 stochastic-free symmetric quantization per tensor with an error
accumulator: compress(g + e) -> q; e' = (g + e) - dequant(q). Over the
slow pod-interconnect this cuts gradient all-reduce bytes 4x (fp32) /
2x (bf16) with provably bounded bias (error feedback). ``top_k`` mode
keeps the largest-|g| fraction instead (sparsity + error feedback).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(g, e):
    """Returns (q int8, scale, new_error)."""
    corrected = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, corrected - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_topk(g, e, frac: float = 0.05):
    """Keep the top-|frac| entries (flattened); returns (values, idx,
    new_error)."""
    corrected = (g.astype(jnp.float32) + e).reshape(-1)
    k = max(int(corrected.size * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(corrected), k)
    kept = corrected[idx]
    deq = jnp.zeros_like(corrected).at[idx].set(kept)
    return kept, idx, (corrected - deq).reshape(g.shape)


def compressed_tree_allreduce(grads, errors, psum_axis: str | None = None):
    """Error-feedback int8 all-reduce over a pytree. Inside shard_map /
    pmap, pass the mapped axis name; outside (single host), reduction is
    the identity and only the quantization error path is exercised."""
    def one(g, e):
        q, scale, e2 = compress_int8(g, e)
        deq = decompress_int8(q, scale)
        if psum_axis is not None:
            deq = jax.lax.pmean(deq, psum_axis)
        return deq.astype(g.dtype), e2
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
