"""Workload-file construction following the paper Sec. V-B exactly:

"We assume that the function arrives at regular intervals every minute.
Then we can calculate the function interval time in that minute by
dividing 60 by the number of function invocations in that minute. After
sorting the invocations of all functions within that minute, the time
difference between adjacent invocations is the inter-arrival time."

``calibrate`` then pins the 2-minute sample's p90 duration to the paper's
1,633 ms anchor (the paper's Fibonacci-calibration analogue).
"""
from __future__ import annotations

import copy
from dataclasses import dataclass

import numpy as np

from ..core.events import Task
from .azure import BUCKET_MS, FIB_N, FunctionMeta, TraceSpec, synth_functions

P90_ANCHOR_MS = 1633.0  # paper Sec. II-E: 90th pct of the 2-min workload


@dataclass
class Workload:
    tasks: list[Task]
    spec: TraceSpec
    scale: float  # calibration factor applied to all durations

    @property
    def iats(self) -> np.ndarray:
        at = np.array([t.arrival for t in self.tasks])
        return np.diff(at)

    def p90_service(self) -> float:
        return float(np.percentile([t.service for t in self.tasks], 90))


def _invocation_times(funcs: list[FunctionMeta], minutes: int) -> list[tuple]:
    """(arrival_ms, func) pairs: regular per-minute spacing, then merged."""
    events: list[tuple[float, FunctionMeta]] = []
    for f in funcs:
        for minute in range(minutes):
            k = int(f.counts[minute])
            if k <= 0:
                continue
            interval = 60_000.0 / k
            for j in range(k):
                events.append((minute * 60_000.0 + j * interval, f))
    events.sort(key=lambda e: (e[0], e[1].func_id))
    return events


def generate_workload(spec: TraceSpec | None = None,
                      calibrate_p90: float | None = P90_ANCHOR_MS) -> Workload:
    spec = spec or TraceSpec()
    rng = np.random.default_rng(spec.seed + 1)
    funcs = synth_functions(spec)
    events = _invocation_times(funcs, spec.minutes)

    services = np.empty(len(events))
    for i, (_, f) in enumerate(events):
        jitter = rng.lognormal(mean=-0.5 * spec.duration_jitter ** 2,
                               sigma=spec.duration_jitter)
        services[i] = BUCKET_MS[f.bucket] * jitter

    scale = 1.0
    if calibrate_p90 is not None:
        scale = calibrate_p90 / float(np.percentile(services, 90))
        services *= scale

    tasks = []
    for i, (arrival, f) in enumerate(events):
        service = float(services[i])
        expected = BUCKET_MS[f.bucket] * scale
        tasks.append(Task(
            tid=i, arrival=arrival, service=service, mem_mb=f.mem_mb,
            func_id=f.func_id, bucket=f.bucket,
            deadline=arrival + spec.edf_slack * expected,
        ))
    return Workload(tasks=tasks, spec=spec, scale=scale)


# -- cluster helpers: load scaling + sharding ---------------------------------

def scale_load(tasks: list[Task], factor: float) -> list[Task]:
    """Compress inter-arrival times by ``factor`` (>1 = heavier load).

    Service demands are untouched — this models more users hitting the
    same function population, the knob a fleet-size sweep turns. Tasks
    are copied; deadlines keep their slack relative to arrival.
    """
    if factor <= 0:
        raise ValueError("load factor must be positive")
    out = []
    for t in tasks:
        c = copy.copy(t)
        slack = t.deadline - t.arrival
        c.arrival = t.arrival / factor
        c.deadline = c.arrival + slack
        out.append(c)
    return out


def shard_tasks(tasks: list[Task], n_shards: int,
                by: str = "hash") -> list[list[Task]]:
    """Statically partition a workload across ``n_shards`` nodes.

    ``by='hash'`` keys on ``func_id`` (every invocation of a function
    lands in one shard — the static analogue of affinity dispatch);
    ``by='interleave'`` deals arrivals round-robin (load-balanced but
    affinity-free). Dynamic routing lives in ``repro.cluster.dispatch``;
    this is for embarrassingly-parallel per-node experiments.
    """
    if n_shards < 1:
        raise ValueError("need at least one shard")
    shards: list[list[Task]] = [[] for _ in range(n_shards)]
    ordered = sorted(tasks, key=lambda t: (t.arrival, t.tid))
    if by == "hash":
        for t in ordered:
            shards[t.func_id % n_shards].append(t)
    elif by == "interleave":
        for i, t in enumerate(ordered):
            shards[i % n_shards].append(t)
    else:
        raise KeyError(f"unknown shard key {by!r}")
    return shards


def keepalive_hints(tasks: list[Task],
                    config: "ContainerConfig | None" = None,
                    ) -> dict[int, float]:
    """Per-function keep-alive signals for the container layer.

    For each function with >= 2 invocations, suggest holding its sandbox
    warm for ``hist_margin`` x the ``hist_pct``-th percentile of its
    observed inter-arrival times (clamped to the config's hist bounds) —
    the trace-driven analogue of the Azure histogram policy (Shahrad et
    al.). The knobs come from the SAME ``ContainerConfig`` the hints
    will feed, so pre-observation hints and the pool's own
    post-observation estimates agree. Functions seen once get no hint;
    the pool falls back to its default TTL for them. Feed the result to
    ``ContainerConfig(prewarm=...)`` (e.g. via ``dataclasses.replace``).
    """
    from ..core.containers import ContainerConfig
    cfg = config if config is not None else ContainerConfig()
    arrivals: dict[int, list[float]] = {}
    for t in sorted(tasks, key=lambda x: x.arrival):
        arrivals.setdefault(t.func_id, []).append(t.arrival)
    hints: dict[int, float] = {}
    for fid, at in arrivals.items():
        if len(at) < 2:
            continue
        iats = np.diff(np.asarray(at))
        ka = float(np.percentile(iats, cfg.hist_pct)) * cfg.hist_margin
        hints[fid] = min(max(ka, cfg.hist_min_ms), cfg.hist_max_ms)
    return hints


def workload_file(w: Workload) -> list[dict]:
    """The paper's workload-file rows: IAT + Fibonacci argument N."""
    rows = []
    prev = 0.0
    for t in w.tasks:
        rows.append({"iat_ms": t.arrival - prev, "fib_n": FIB_N[t.bucket],
                     "mem_mb": t.mem_mb, "func_id": t.func_id})
        prev = t.arrival
    return rows
