"""repro.traces — Azure-like FaaS workload synthesis (DESIGN.md Sec. 5)."""
from .azure import (BUCKET_MS, BUCKET_WEIGHTS, FIB_N, PHI, FunctionMeta,
                    TraceSpec, synth_functions)
from .workload import (P90_ANCHOR_MS, Workload, generate_workload,
                       keepalive_hints, scale_load, shard_tasks,
                       workload_file)

__all__ = [
    "BUCKET_MS", "BUCKET_WEIGHTS", "FIB_N", "PHI", "FunctionMeta",
    "TraceSpec", "synth_functions", "P90_ANCHOR_MS", "Workload",
    "generate_workload", "keepalive_hints", "scale_load", "shard_tasks",
    "workload_file",
]
