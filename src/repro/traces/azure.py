"""Azure-'20-like FaaS trace synthesis.

The Azure trace itself is not redistributable in this offline container;
we synthesize a workload matched to the statistics the paper uses
(DESIGN.md Sec. 5):

* duration CDF: ~80% of invocations < 1 s, heavy right tail (Fig. 2 left);
  p90 of the 2-minute sample is CALIBRATED to the paper's 1,633 ms anchor;
* function durations live on a Fibonacci ladder: the paper calibrates
  fib(36..46) binaries, whose run time grows by the golden ratio per step;
* burstiness: per-minute per-function invocation counts with lognormal
  burst multipliers (Fig. 2 right);
* memory sizes: >90% of functions < 400 MB;
* volume: first two minutes ~= 12,442 invocations after the paper's 100x
  downscale.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

PHI = (1.0 + 5.0 ** 0.5) / 2.0

# fib(36..51) calibrated durations (ms): golden-ratio ladder anchored at
# ~80 ms for N=36 (matches the paper's Xeon E5-2697v4 measurements scale).
# The paper calibrates N=36..46; we keep extra rungs for the Azure
# minutes-long tail so the overload regime (FIFO p99 response of minutes,
# Table I) is reproduced.
FIB_N = tuple(range(36, 52))
BUCKET_MS = tuple(80.0 * PHI ** i for i in range(len(FIB_N)))

# INVOCATION-weighted bucket mass: ~85% of invocations below 1 s
# (Azure Fig. 2), p90 lands on the 1,633 ms anchor after calibration,
# ~1% are minute-scale monsters that carry roughly half the CPU-seconds
# (which is exactly what makes scheduling policy choice matter).
BUCKET_WEIGHTS = (0.17, 0.16, 0.15, 0.14, 0.13, 0.10, 0.075,
                  0.030, 0.016, 0.007, 0.005, 0.005, 0.005,
                  0.004, 0.002, 0.001)

AZURE_MEMORY_MB = (128, 192, 256, 384, 512, 1024, 2048, 4096)
AZURE_MEMORY_P = (0.45, 0.15, 0.15, 0.15, 0.05, 0.03, 0.015, 0.005)


@dataclass
class TraceSpec:
    minutes: int = 2
    n_functions: int = 250
    invocations_per_min: float = 6221.0   # => ~12,442 in two minutes
    burst_sigma: float = 0.55             # lognormal per-function-minute burst
    duration_jitter: float = 0.08         # per-invocation lognormal sigma
    zipf_s: float = 1.1                   # function popularity skew
    edf_slack: float = 2.0                # deadline = arrival + slack*expected
    seed: int = 0


@dataclass
class FunctionMeta:
    func_id: int
    bucket: int                 # index into BUCKET_MS
    mem_mb: int
    rate: float                 # base invocations/min
    counts: np.ndarray = field(default=None)  # per-minute invocation counts


def _assign_buckets(pop: np.ndarray, weights) -> np.ndarray:
    """Stratified bucket assignment: functions (desc. by popularity) are
    greedily given the bucket with the largest remaining INVOCATION-mass
    deficit, so the realized invocation-weighted duration distribution
    matches ``weights`` closely (low variance across seeds)."""
    total = pop.sum()
    target = np.asarray(weights) * total
    assigned = np.zeros(len(target))
    out = np.zeros(len(pop), dtype=np.int64)
    order = np.argsort(-pop)
    for i in order:
        b = int(np.argmax(target - assigned))
        out[i] = b
        assigned[b] += pop[i]
    return out


def synth_functions(spec: TraceSpec) -> list[FunctionMeta]:
    """Function population: bucket (duration class), memory, popularity,
    and bursty per-minute invocation counts."""
    rng = np.random.default_rng(spec.seed)
    n = spec.n_functions
    mems = rng.choice(AZURE_MEMORY_MB, size=n, p=AZURE_MEMORY_P)
    # Zipf-ish popularity, normalized to the target aggregate rate.
    ranks = np.arange(1, n + 1, dtype=np.float64)
    pop = ranks ** (-spec.zipf_s)
    rng.shuffle(pop)
    pop *= spec.invocations_per_min / pop.sum()
    buckets = _assign_buckets(pop, BUCKET_WEIGHTS)
    target = spec.invocations_per_min * spec.minutes
    lam = np.empty((n, spec.minutes))
    for i in range(n):
        burst = rng.lognormal(mean=-0.5 * spec.burst_sigma ** 2,
                              sigma=spec.burst_sigma, size=spec.minutes)
        lam[i] = np.maximum(pop[i] * burst, 0.0)
    counts = rng.poisson(lam)
    # Renormalize so the realized volume matches the paper's 12,442
    # first-two-minutes count (burst draws have high variance).
    realized = counts.sum()
    if realized > 0 and abs(realized - target) / target > 0.02:
        counts = rng.poisson(lam * (target / realized))
    funcs = []
    for i in range(n):
        funcs.append(FunctionMeta(func_id=i, bucket=int(buckets[i]),
                                  mem_mb=int(mems[i]), rate=float(pop[i]),
                                  counts=counts[i]))
    return funcs
